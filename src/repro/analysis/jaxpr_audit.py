"""Jaxpr-level purity audit of the registered consolidation hot paths.

Every entry point that claims to be device-resident is registered here with a
**purity tier** and a builder that constructs production-shaped example
inputs (T = 230 -- the paper's 10 x 23 grid -- realistic fleet/batch sizes).
The auditor lowers the entry to its ClosedJaxpr (no compilation, no
execution) and walks every equation, recursing through ``pjit`` /
``while`` / ``scan`` / ``cond`` sub-jaxprs, checking the tier's contract:

  host-callback      ``pure_callback`` / ``io_callback`` / ``debug_callback``
                     (debug prints lower to the latter) anywhere in a device
                     tier: each is a host round-trip in a path that promises
                     zero host syncs.
  float64-leak       a non-weak float64 intermediate on a device tier.
                     Tracing runs under ``enable_x64`` so un-annotated numpy
                     constants surface as f64 instead of being silently
                     downcast by the global x64=off default; *weak*-typed
                     f64 scalars (python literals) are fine -- they never
                     force promotion -- and int64 iota artifacts of the
                     forced flag are ignored.
  dynamic-shape      any abstract value whose shape is not a tuple of
                     concrete ints: the fixed-shape contract every jitted
                     hot path relies on for cache stability.
  donation           declared donation that can never apply: a donated input
                     with no output of matching shape/dtype cannot alias, so
                     the "in-place" ring push would silently copy. On
                     backends that implement donation, XLA's "donated buffer
                     not used" warnings during compilation are promoted to
                     findings too (skipped on CPU, which never donates).
  vmem-budget /      every ``pallas_call`` equation found in the trace:
  grid-divisibility  sum of block bytes (block_shape x dtype) per kernel
                     against the per-platform VMEM budget, and each operand's
                     array dims divisible by its block dims (a silent
                     mis-tile otherwise).

Registering a new hot path is one ``HotEntry`` (DESIGN.md §12): name, tier,
and a zero-argument builder returning ``(fn, args)``. The builders below use
*fake* dynamics tables (random-free, deterministic constants) -- tracing
only consumes shapes and dtypes, so the audit never pays for profiling.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import Finding

try:  # the jaxpr types moved between jax versions
    from jax.extend import core as jcore  # noqa: F401  (newer releases)
    _Jaxpr = jcore.Jaxpr
    _ClosedJaxpr = getattr(jcore, "ClosedJaxpr", None)
except Exception:  # pragma: no cover
    jcore = None
    _Jaxpr = None
    _ClosedJaxpr = None
if _Jaxpr is None or _ClosedJaxpr is None:  # pragma: no cover
    import jax.core as _jax_core

    _Jaxpr = _jax_core.Jaxpr
    _ClosedJaxpr = _jax_core.ClosedJaxpr

#: primitives that are host round-trips by construction
CALLBACK_PRIMITIVES = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "callback",
     "outside_call", "host_callback_call"})

#: per-platform on-chip scratch budget for one Pallas kernel's resident
#: blocks. TPU VMEM is ~16 MiB/core; the budget keeps headroom for compiler
#: spills and semaphores rather than sailing at the physical limit.
VMEM_LIMIT_BYTES = 16 * 2**20
VMEM_HEADROOM = 0.75

# -- purity tiers --------------------------------------------------------------
#: strict device residency: the tier of every hot-loop entry point
TIER_DEVICE = "device"
#: device-resident but f64 allowed (reference/oracle paths lowered on CPU)
TIER_DEVICE_F64 = "device-f64"
#: host orchestration: callbacks allowed; only shape stability is checked
TIER_HOST = "host"

#: relaxations granted by each tier (checks *skipped* for members)
TIER_RELAXATIONS: dict[str, frozenset[str]] = {
    TIER_DEVICE: frozenset(),
    TIER_DEVICE_F64: frozenset({"float64-leak"}),
    TIER_HOST: frozenset({"float64-leak", "host-callback"}),
}


@dataclasses.dataclass(frozen=True)
class HotEntry:
    """One registered hot path: what it promises and how to trace it."""

    name: str
    tier: str
    #: () -> (callable, example_args): the callable is traced (not run) with
    #: the args; keyword config is baked in by the builder via a lambda
    build: Callable[[], tuple[Callable, tuple]]
    #: the entry lowers through ``pl.pallas_call`` (golden-snapshot set)
    pallas: bool = False
    #: the entry declares buffer donation; applicability is verified
    donated: bool = False

    def trace(self) -> "tuple[_ClosedJaxpr, bool]":
        """(closed_jaxpr, x64_traced): the jaxpr all checks walk.

        The float64-leak check wants tracing under ``enable_x64`` -- with
        x64 globally off (the shipping config) every f64 is silently
        downcast at trace time and a leak can never appear in the jaxpr.
        Some entries cannot trace under forced x64 (int32/int64 branch
        mismatches that are artifacts of the flag, not bugs); those fall
        back to the default-config trace, where the f64 check is vacuous
        but every other check is unaffected.
        """
        fn, args = self.build()
        try:
            with jax.experimental.enable_x64():
                return jax.make_jaxpr(fn)(*args), True
        except Exception:
            return jax.make_jaxpr(fn)(*args), False


# -- example-input builders ----------------------------------------------------
# Deterministic, profiling-free: tracing consumes shapes/dtypes only, so the
# dynamics tables are constants with the right layout, at production scale
# (T = 230 everywhere; fleet/batch sizes representative of BENCH tiers).

_T = 230  # len(RS_GRID) * len(FS_GRID): the paper's profiling grid


def _f32(shape, fill=0.0):
    return jnp.full(shape, fill, jnp.float32)


def _servers(m: int):
    import dataclasses as dc

    from ..core.server import M1, M2

    base = [M1, M2]
    return [dc.replace(base[i % 2], name=f"{base[i % 2].name}-{i}")
            for i in range(m)]


def _cluster(m: int):
    from ..core.binpack_jax import PackedCluster

    D = [np.full((_T, _T), 0.05, np.float32) for _ in range(m)]
    return PackedCluster.build(_servers(m), D, alpha=1.3)


def _dynamics(m: int):
    from ..core.engine_jax import PackedDynamics

    logd = _f32((m, _T, _T), math.log1p(-0.05))
    return PackedDynamics(
        solo=_f32((m, _T), 1e6), base_lost=_f32((m, _T), 5e5),
        log_keep=logd, log_lost=logd * 2.0,
        comp_bytes=_f32((m, _T), 1e5), tol_budget=_f32((m,), 1e7))


def _ring_block(B: int, fleet: int):
    from ..telemetry.log import RingBlock

    return RingBlock.build(
        wtype=jnp.arange(B, dtype=jnp.int32) % _T,
        server=jnp.arange(B, dtype=jnp.int32) % fleet,
        duration=_f32((B,), 1.0), y=_f32((B,), -0.1),
        co=_f32((B, _T), 0.01), lost_frac=_f32((B,), 0.0),
        valid=_f32((B,), 1.0))


def _estimator_hypers(use_pallas: bool, interpret: bool) -> dict:
    return dict(lr=0.5, decay=0.997, step_damp=0.5, solo_eps=0.05,
                max_lost_frac=0.5, use_pallas=use_pallas, interpret=interpret)


def _build_run_trace():
    from ..core.engine_jax import run_trace

    m, n = 4, 16
    cluster, dyn = _cluster(m), _dynamics(m)
    arr_time = jnp.cumsum(_f32((n,), 0.5))
    arr_type = jnp.arange(n, dtype=jnp.int32) % _T
    arr_bytes = _f32((n,), 1e6)
    fn = lambda c, d, t, ty, b: run_trace(c, d, t, ty, b, telemetry=True)
    return fn, (cluster, dyn, arr_time, arr_type, arr_bytes)


def _build_update_device():
    from ..telemetry.estimator import DeviceEstimatorState, _update_device

    state = DeviceEstimatorState(
        L_t=_f32((_T, _T)), log_b=_f32((_T,)), n_pair_t=_f32((_T, _T)),
        n_base=_f32((_T,)), n_obs=jnp.int32(0))
    block = _ring_block(B=128, fleet=1)
    hypers = _estimator_hypers(use_pallas=True, interpret=False)
    fn = lambda st, blk, srv: _update_device(st, blk, srv, **hypers)
    return fn, (state, block, jnp.int32(-1))


def _build_update_bank():
    from ..telemetry.estimator import DeviceEstimatorState, _update_bank

    m = 4
    state = DeviceEstimatorState(
        L_t=_f32((m, _T, _T)), log_b=_f32((m, _T)), n_pair_t=_f32((m, _T, _T)),
        n_base=_f32((m, _T)), n_obs=jnp.zeros((m,), jnp.int32))
    block = _ring_block(B=128, fleet=m)
    hypers = _estimator_hypers(use_pallas=False, interpret=False)
    fn = lambda st, blk: _update_bank(st, blk, **hypers)
    return fn, (state, block)


def _build_cusum_update():
    from ..fleet.detect import CusumState, _cusum_update

    m, rows, B = 4, 4, 128
    state = CusumState(
        stat=_f32((m, 2)), level=_f32((m,)), n=_f32((m,)),
        pool_level=_f32((rows,)), pool_n=_f32((rows,)))
    block = _ring_block(B=B, fleet=m)
    log_b, L_t = _f32((rows, _T)), _f32((rows, _T, _T))
    row_map = jnp.arange(m, dtype=jnp.int32) % rows
    fn = lambda st, blk, lb, lt, rm: _cusum_update(
        st, blk, lb, lt, rm, k=0.25, level_decay=0.9, max_lost_frac=0.5)
    return fn, (state, block, log_b, L_t, row_map)


def _build_ring_push():
    from ..core.engine_jax import EngineTrace
    from ..telemetry.log import RingBlock, _ring_write_trace

    n, cap = 64, 256
    buf = RingBlock(
        ints=jnp.full((cap, 2), -1, jnp.int32),
        scalars=jnp.zeros((cap, 6), jnp.float32),
        co=jnp.zeros((cap, _T), jnp.float32))
    trace = EngineTrace(
        placement=jnp.zeros((n,), jnp.int32),
        was_queued=jnp.zeros((n,), bool),
        place_time=_f32((n,), 0.0), finish_time=_f32((n,), 1.0),
        makespan=jnp.float32(1.0), max_deg=jnp.float32(0.0),
        deadlock=jnp.asarray(False),
        obs_co=_f32((n, _T), 0.01), obs_lost=_f32((n,), 0.0),
        obs_logr=_f32((n,), -0.1))
    arr_type = jnp.arange(n, dtype=jnp.int32) % _T
    fn = lambda b, tr, ty, p: _ring_write_trace(b, tr, ty, p, 1e-12)
    return fn, (buf, trace, arr_type, jnp.int32(0))


def _build_closed_loop():
    from ..core.closed_loop import (
        ClosedLoopConfig,
        LoopCarry,
        SegmentIn,
        run_closed_loop,
    )
    from ..fleet.detect import CusumState
    from ..telemetry.estimator import DeviceEstimatorState
    from ..telemetry.log import RingBlock

    m, n_seg, S_cap, cap = 4, 4, 4, 256
    R = n_seg  # requeue capacity: one segment's worth, as the engine packs it
    cluster = _cluster(m)
    dyn_stack = jax.tree_util.tree_map(lambda a: a[None], _dynamics(m))
    bank = DeviceEstimatorState(
        L_t=_f32((m, _T, _T)), log_b=_f32((m, _T)),
        n_pair_t=_f32((m, _T, _T)), n_base=_f32((m, _T)),
        n_obs=jnp.zeros((m,), jnp.int32))
    ring = RingBlock(
        ints=jnp.full((cap, 2), -1, jnp.int32),
        scalars=jnp.zeros((cap, 6), jnp.float32),
        co=jnp.zeros((cap, _T), jnp.float32))
    carry = LoopCarry(
        bank=bank, det=CusumState.zeros(m),
        row_map=jnp.arange(m, dtype=jnp.int32),
        read_row=jnp.arange(m, dtype=jnp.int32),
        active=jnp.ones((m,), bool), seen=jnp.int32(0),
        req_type=jnp.zeros((R,), jnp.int32),
        req_bytes=jnp.ones((R,), jnp.float32), req_n=jnp.int32(0),
        ring=ring, ring_ptr=jnp.int32(0), ring_total=jnp.int32(0))
    xs = SegmentIn(
        arr_time=_f32((S_cap, n_seg), 0.5),
        arr_type=jnp.tile(jnp.arange(n_seg, dtype=jnp.int32) % _T, (S_cap, 1)),
        arr_bytes=_f32((S_cap, n_seg), 1e6),
        dyn_idx=jnp.zeros((S_cap,), jnp.int32),
        seg_valid=jnp.ones((S_cap,), bool))
    Lp_t, logb = _f32((m, _T, _T)), _f32((m, _T))
    config = ClosedLoopConfig(fleet=True)
    fn = lambda c, d, lp, lb, cr, x: run_closed_loop(c, d, lp, lb, cr, x, config)
    return fn, (cluster, dyn_stack, Lp_t, logb, carry, xs)


def _build_run_trace_metrics():
    """The metrics-instrumented event loop: same shapes as the plain entry,
    but with the in-carry MetricFrame threaded through -- the instrumentation
    must satisfy the same device-purity contract as the loop it measures."""
    from ..core.engine_jax import run_trace

    m, n = 4, 16
    cluster, dyn = _cluster(m), _dynamics(m)
    arr_time = jnp.cumsum(_f32((n,), 0.5))
    arr_type = jnp.arange(n, dtype=jnp.int32) % _T
    arr_bytes = _f32((n,), 1e6)
    fn = lambda c, d, t, ty, b: run_trace(
        c, d, t, ty, b, telemetry=True, metrics=True)
    return fn, (cluster, dyn, arr_time, arr_type, arr_bytes)


def _build_closed_loop_metrics():
    """Metrics-instrumented multi-segment loop (fleet + metrics on): the
    merge/count/observe ops in the scan body are part of the hot path when
    the flag is set, so they get their own registry row."""
    from ..core.closed_loop import (
        ClosedLoopConfig,
        LoopCarry,
        SegmentIn,
        run_closed_loop,
    )
    from ..fleet.detect import CusumState
    from ..obs import metrics as obs_metrics
    from ..telemetry.estimator import DeviceEstimatorState
    from ..telemetry.log import RingBlock

    m, n_seg, S_cap, cap = 4, 4, 4, 256
    R = n_seg
    cluster = _cluster(m)
    dyn_stack = jax.tree_util.tree_map(lambda a: a[None], _dynamics(m))
    bank = DeviceEstimatorState(
        L_t=_f32((m, _T, _T)), log_b=_f32((m, _T)),
        n_pair_t=_f32((m, _T, _T)), n_base=_f32((m, _T)),
        n_obs=jnp.zeros((m,), jnp.int32))
    ring = RingBlock(
        ints=jnp.full((cap, 2), -1, jnp.int32),
        scalars=jnp.zeros((cap, 6), jnp.float32),
        co=jnp.zeros((cap, _T), jnp.float32))
    carry = LoopCarry(
        bank=bank, det=CusumState.zeros(m),
        row_map=jnp.arange(m, dtype=jnp.int32),
        read_row=jnp.arange(m, dtype=jnp.int32),
        active=jnp.ones((m,), bool), seen=jnp.int32(0),
        req_type=jnp.zeros((R,), jnp.int32),
        req_bytes=jnp.ones((R,), jnp.float32), req_n=jnp.int32(0),
        ring=ring, ring_ptr=jnp.int32(0), ring_total=jnp.int32(0),
        metrics=obs_metrics.zeros(m))
    xs = SegmentIn(
        arr_time=_f32((S_cap, n_seg), 0.5),
        arr_type=jnp.tile(jnp.arange(n_seg, dtype=jnp.int32) % _T, (S_cap, 1)),
        arr_bytes=_f32((S_cap, n_seg), 1e6),
        dyn_idx=jnp.zeros((S_cap,), jnp.int32),
        seg_valid=jnp.ones((S_cap,), bool))
    Lp_t, logb = _f32((m, _T, _T)), _f32((m, _T))
    config = ClosedLoopConfig(fleet=True, metrics=True)
    fn = lambda c, d, lp, lb, cr, x: run_closed_loop(c, d, lp, lb, cr, x, config)
    return fn, (cluster, dyn_stack, Lp_t, logb, carry, xs)


def _build_run_trace_record():
    """The recorder-instrumented event loop: same shapes as the plain entry,
    with the decision flight recorder's ring threaded through the carry --
    the provenance scatter per event must satisfy the same device-purity
    contract as the loop it records (DESIGN.md section 16)."""
    from ..core.engine_jax import run_trace

    m, n = 4, 16
    cluster, dyn = _cluster(m), _dynamics(m)
    arr_time = jnp.cumsum(_f32((n,), 0.5))
    arr_type = jnp.arange(n, dtype=jnp.int32) % _T
    arr_bytes = _f32((n,), 1e6)
    fn = lambda c, d, t, ty, b: run_trace(
        c, d, t, ty, b, telemetry=True, record=True)
    return fn, (cluster, dyn, arr_time, arr_type, arr_bytes)


def _build_closed_loop_record():
    """Recorder-on multi-segment loop (fleet + record): the ring rides the
    scan carry next to the telemetry ring; the per-decision row writes are
    part of the hot path when the flag is set."""
    from ..core.closed_loop import ClosedLoopConfig, run_closed_loop
    from ..obs import recorder as obs_recorder

    fn_args = _build_closed_loop_metrics()
    carry = fn_args[1][4]._replace(rec=obs_recorder.init(256))
    config = ClosedLoopConfig(fleet=True, metrics=True, record=True)
    fn = lambda c, d, lp, lb, cr, x: run_closed_loop(c, d, lp, lb, cr, x, config)
    return fn, fn_args[1][:4] + (carry,) + fn_args[1][5:]


def _server_axis_1():
    """A 1-device mesh ServerAxis: traces the full shard_map path (size-1
    collectives included) on any host, so the sharded entries stay
    registered and auditable in single-device CI."""
    from ..distributed.server_axis import ServerAxis

    return ServerAxis.over_host_devices(1)


def _build_greedy_sharded():
    """The sharded Q x m candidate scorer: score-local-then-argmax-allreduce
    over the server mesh (collectives allowed at tier device; host
    callbacks are banned here exactly as on the dense entries)."""
    from ..core.binpack_jax import greedy_sequence_sharded

    m, n = 4, 16
    axis = _server_axis_1()
    cluster = _cluster(m)
    counts = _f32((m, _T))
    wtypes = jnp.arange(n, dtype=jnp.int32) % _T
    fn = lambda c, cnt, wt: greedy_sequence_sharded(c, cnt, wt, axis)
    return fn, (cluster, counts, wtypes)


def _build_closed_loop_sharded():
    """The whole multi-segment loop under shard_map (1-device mesh): every
    per-segment collective the 10k-server layout runs, host-callback-free."""
    fn_args = _build_closed_loop()
    from ..core.closed_loop import ClosedLoopConfig, run_closed_loop

    config = ClosedLoopConfig(fleet=True, axis=_server_axis_1())
    fn = lambda c, d, lp, lb, cr, x: run_closed_loop(c, d, lp, lb, cr, x, config)
    return fn, fn_args[1]


def _build_consolidation_scores():
    from ..kernels.consolidation import consolidation_scores

    m, Q = 16, 64
    cluster = _cluster(m)
    counts = _f32((m, _T))
    fs_res = cluster.resident * cluster.fs[None, :]
    wtypes = jnp.arange(Q, dtype=jnp.int32) % _T
    fn = lambda c, D, rs, fr, bud, wt: consolidation_scores(
        c, D, rs, fr, bud, wt, interpret=False)
    return fn, (counts, cluster.D, cluster.rs, fs_res, cluster.llc_budget, wtypes)


def _build_pair_scatter():
    from ..kernels.telemetry import pair_scatter

    B, K = 256, 2
    types = jnp.arange(B, dtype=jnp.int32) % _T
    cbar = _f32((B, _T), 0.01)
    vals = _f32((K, B), 0.5)
    fn = lambda t, c, v: pair_scatter(t, c, v, interpret=False)
    return fn, (types, cbar, vals)


def _build_pallas_scorer():
    from ..core.engine import make_scorer

    m, Q = 16, 64
    cluster = _cluster(m)
    counts = _f32((m, _T))
    wtypes = jnp.arange(Q, dtype=jnp.int32) % _T
    scorer = make_scorer("pallas", interpret=False)
    return scorer, (cluster, counts, wtypes)


# model-serving kernels (the co-tenant workloads the consolidation fleet
# runs): not part of the scheduler's closed loop, but every pallas_call in
# the repo is budget-audited, so they register at the same device tier

def _build_rwkv6_scan():
    from ..kernels.rwkv6_scan import rwkv6_scan

    N, S, dh = 4, 64, 64
    seq = _f32((N, S, dh), 0.1)
    fn = lambda r, k, v, w, u, s0: rwkv6_scan(
        r, k, v, w, u, s0, chunk=32, interpret=False)
    return fn, (seq, seq, seq, _f32((N, S, dh), -0.1), _f32((N, dh), 0.1),
                _f32((N, dh, dh)))


def _build_flash_attention():
    from ..kernels.flash_attention import flash_attention

    N, S, dh = 4, 512, 64
    seq = _f32((N, S, dh), 0.1)
    fn = lambda q, k, v: flash_attention(
        q, k, v, causal=True, block_q=256, block_k=256, interpret=False)
    return fn, (seq, seq, seq)


def _build_mamba_scan():
    from ..kernels.mamba_scan import mamba_scan

    B, S, E, N = 2, 64, 512, 16
    fn = lambda da, dbu, c, h0: mamba_scan(
        da, dbu, c, h0, chunk=64, eblock=512, interpret=False)
    return fn, (_f32((B, S, E, N), 0.9), _f32((B, S, E, N), 0.1),
                _f32((B, S, N), 0.1), _f32((B, E, N)))


#: the registry: every entry point the device-resident closed loop stands on
REGISTRY: tuple[HotEntry, ...] = (
    HotEntry("engine_jax.run_trace", TIER_DEVICE, _build_run_trace),
    HotEntry("telemetry.estimator.update_device", TIER_DEVICE,
             _build_update_device, pallas=True),
    HotEntry("telemetry.estimator.update_bank", TIER_DEVICE, _build_update_bank),
    HotEntry("fleet.detect.cusum_update", TIER_DEVICE, _build_cusum_update),
    HotEntry("telemetry.log.ring_push", TIER_DEVICE, _build_ring_push,
             donated=True),
    HotEntry("core.closed_loop.run_closed_loop", TIER_DEVICE,
             _build_closed_loop),
    HotEntry("engine_jax.run_trace[metrics]", TIER_DEVICE,
             _build_run_trace_metrics),
    HotEntry("core.closed_loop.run_closed_loop[metrics]", TIER_DEVICE,
             _build_closed_loop_metrics),
    HotEntry("engine_jax.run_trace[record]", TIER_DEVICE,
             _build_run_trace_record),
    HotEntry("core.closed_loop.run_closed_loop[record]", TIER_DEVICE,
             _build_closed_loop_record),
    HotEntry("binpack_jax.greedy_sequence[sharded]", TIER_DEVICE,
             _build_greedy_sharded),
    HotEntry("core.closed_loop.run_closed_loop[sharded]", TIER_DEVICE,
             _build_closed_loop_sharded),
    HotEntry("kernels.consolidation.consolidation_scores", TIER_DEVICE,
             _build_consolidation_scores, pallas=True),
    HotEntry("kernels.telemetry.pair_scatter", TIER_DEVICE, _build_pair_scatter,
             pallas=True),
    HotEntry("engine.make_scorer[pallas]", TIER_DEVICE, _build_pallas_scorer,
             pallas=True),
    HotEntry("kernels.rwkv6_scan.rwkv6_scan", TIER_DEVICE, _build_rwkv6_scan,
             pallas=True),
    HotEntry("kernels.flash_attention.flash_attention", TIER_DEVICE,
             _build_flash_attention, pallas=True),
    HotEntry("kernels.mamba_scan.mamba_scan", TIER_DEVICE, _build_mamba_scan,
             pallas=True),
)

#: repo-relative files whose ``pallas_call`` sites the registry exercises;
#: ``ast_rules`` fails any pallas_call in a file not listed here, so a new
#: kernel cannot land without a registered budget entry (DESIGN.md §12)
PALLAS_COVERAGE = frozenset({
    "src/repro/kernels/telemetry.py",
    "src/repro/kernels/consolidation.py",
    "src/repro/kernels/rwkv6_scan.py",
    "src/repro/kernels/flash_attention.py",
    "src/repro/kernels/mamba_scan.py",
})


def get_entry(name: str) -> HotEntry:
    for e in REGISTRY:
        if e.name == name:
            return e
    raise KeyError(f"no registered hot entry {name!r}")


# -- the walker ----------------------------------------------------------------

def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        if isinstance(v, _ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, _Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, _ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, _Jaxpr):
                    yield x


def iter_eqns(jaxpr):
    """Every equation of ``jaxpr``, recursing into sub-jaxprs (pjit, control
    flow, pallas kernel bodies -- anything carrying a jaxpr in its params)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def primitive_counts(jaxpr) -> dict[str, int]:
    """Histogram of primitive names over the whole (recursive) jaxpr -- the
    golden-snapshot quantity: a changed count means the lowering changed."""
    counts: dict[str, int] = {}
    for eqn in iter_eqns(jaxpr):
        counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
    return dict(sorted(counts.items()))


def _avals_of(eqn):
    for v in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "shape"):
            yield v, aval


def _check_eqns(entry: HotEntry, closed) -> list[Finding]:
    relaxed = TIER_RELAXATIONS[entry.tier]
    findings: list[Finding] = []
    seen: set[tuple[str, str]] = set()  # dedupe (rule, detail) per entry

    def add(rule: str, detail: str):
        if rule in relaxed or (rule, detail) in seen:
            return
        seen.add((rule, detail))
        findings.append(Finding("jaxpr", rule, entry.name, detail))

    for eqn in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if name in CALLBACK_PRIMITIVES:
            cb = eqn.params.get("callback", "")
            add("host-callback", f"primitive '{name}' ({cb})"[:160])
        for v, aval in _avals_of(eqn):
            dtype = getattr(aval, "dtype", None)
            if (dtype is not None and dtype in (jnp.float64, jnp.complex128)
                    and not getattr(aval, "weak_type", False)):
                add("float64-leak", f"{dtype} value in '{name}'")
            if not all(isinstance(d, (int, np.integer)) for d in aval.shape):
                add("dynamic-shape", f"shape {aval.shape} in '{name}'")
    return findings


# -- donation ------------------------------------------------------------------

def _check_donation(entry: HotEntry, closed) -> list[Finding]:
    """Donation declared on a pjit whose outputs can never absorb the buffer.

    A donated input aliases an output only when some output matches its
    shape/dtype; a donated invar with no match is a contract violation (the
    'in-place' update silently copies). Purely structural, so it runs on any
    backend -- the XLA runtime warning promotion complements it on devices
    that actually implement donation (``runtime_donation_findings``).
    """
    findings: list[Finding] = []
    for eqn in iter_eqns(closed.jaxpr):
        donated = eqn.params.get("donated_invars")
        if not donated or not any(donated):
            continue
        inner = eqn.params.get("jaxpr")
        jx = inner.jaxpr if isinstance(inner, _ClosedJaxpr) else inner
        if jx is None:  # pragma: no cover
            continue
        outs = [(tuple(v.aval.shape), str(v.aval.dtype)) for v in jx.outvars]
        for dv, var in zip(donated, jx.invars):
            if not dv:
                continue
            sig = (tuple(var.aval.shape), str(var.aval.dtype))
            if sig not in outs:
                findings.append(Finding(
                    "donation", "donation-unapplicable", entry.name,
                    f"donated {sig[1]}{list(sig[0])} has no matching output"))
    if entry.donated and not any(
            any(eqn.params.get("donated_invars") or ())
            for eqn in iter_eqns(closed.jaxpr)):
        findings.append(Finding(
            "donation", "donation-missing", entry.name,
            "entry is registered as donating but no pjit declares donation"))
    return findings


def runtime_donation_findings(entry: HotEntry) -> list[Finding]:
    """Promote XLA's "donated buffer not used" warnings to findings.

    Only meaningful where the backend implements donation -- CPU never
    does, so there the check is skipped rather than reporting noise.
    """
    if jax.default_backend() == "cpu":
        return []
    fn, args = entry.build()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        jax.jit(fn).lower(*args).compile()
    return [
        Finding("donation", "donation-unapplied", entry.name, str(w.message)[:200])
        for w in caught if "donat" in str(w.message).lower()]


# -- pallas VMEM / grid budget -------------------------------------------------

def _block_mappings(eqn):
    gm = eqn.params.get("grid_mapping")
    if gm is None:  # pragma: no cover -- pallas internals moved
        return None, ()
    return gm, getattr(gm, "block_mappings", ())


def pallas_budget_findings(entry: HotEntry, closed) -> tuple[list[Finding], list[dict]]:
    """VMEM residency + grid-divisibility for every pallas_call in the trace.

    The resident-block estimate is the sum over operands of block_shape x
    itemsize -- what the BlockSpecs pin in VMEM simultaneously (double
    buffering and scratch come on top, hence the headroom factor).
    """
    findings: list[Finding] = []
    sites: list[dict] = []
    budget = int(VMEM_LIMIT_BYTES * VMEM_HEADROOM)
    for eqn in iter_eqns(closed.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        gm, mappings = _block_mappings(eqn)
        if gm is None:
            continue
        total = 0
        for bm in mappings:
            shape_dtype = getattr(bm, "array_shape_dtype", None)
            block = [d for d in bm.block_shape if isinstance(d, (int, np.integer))]
            if shape_dtype is None:  # pragma: no cover
                continue
            itemsize = np.dtype(shape_dtype.dtype).itemsize
            total += int(np.prod(block, dtype=np.int64)) * itemsize
            arr = shape_dtype.shape
            for a, b in zip(arr, bm.block_shape):
                if isinstance(b, (int, np.integer)) and b > 0 and a % b:
                    findings.append(Finding(
                        "vmem", "grid-divisibility", entry.name,
                        f"array dim {a} not divisible by block dim {b} "
                        f"(array {list(arr)}, block {list(bm.block_shape)})"))
        sites.append({"entry": entry.name, "grid": list(getattr(gm, "grid", ())),
                      "resident_bytes": total, "budget_bytes": budget})
        if total > budget:
            findings.append(Finding(
                "vmem", "vmem-budget", entry.name,
                f"resident blocks {total / 2**20:.2f} MiB exceed the "
                f"{budget / 2**20:.2f} MiB budget "
                f"({VMEM_HEADROOM:.0%} of {VMEM_LIMIT_BYTES // 2**20} MiB VMEM)"))
    return findings, sites


# -- driver --------------------------------------------------------------------

def audit_entry(entry: HotEntry) -> tuple[list[Finding], dict]:
    """All jaxpr-level checks for one registered entry."""
    closed, x64_traced = entry.trace()
    findings = _check_eqns(entry, closed)
    findings += _check_donation(entry, closed)
    vmem_findings, sites = pallas_budget_findings(entry, closed)
    findings += vmem_findings
    findings += runtime_donation_findings(entry) if entry.donated else []
    info = {"primitives": primitive_counts(closed.jaxpr),
            "pallas_sites": sites, "x64_traced": x64_traced}
    return findings, info


def run_jaxpr_audit(names: "Sequence[str] | None" = None,
                    stats: "dict | None" = None) -> list[Finding]:
    """Audit every registered entry (or the named subset)."""
    findings: list[Finding] = []
    entry_stats: dict[str, dict] = {}
    for entry in REGISTRY:
        if names is not None and entry.name not in names:
            continue
        fs, info = audit_entry(entry)
        findings += fs
        entry_stats[entry.name] = {
            "tier": entry.tier, "findings": len(fs),
            "pallas_sites": info["pallas_sites"],
            "n_primitives": sum(info["primitives"].values()),
            "x64_traced": info["x64_traced"],
        }
    if stats is not None:
        stats["jaxpr"] = entry_stats
    return findings
