"""Device-purity auditor: static invariant checks for the consolidation hot path.

The ROADMAP's next frontier is a fully device-resident closed loop with "no
host in the hot path" -- but nothing *proves* a hot path is host-free,
retrace-free, or within Pallas VMEM budgets. Regressions creep silently: a
per-segment ``np.asarray`` pull re-appears behind a property, a debug print
survives into a jitted program, a donated buffer stops aliasing, a cache key
starts churning. The paper's contribution is a *guaranteed floor* under
consolidation (arXiv:1303.7270); this package is the analogous floor for the
implementation -- a set of machine-checkable purity/shape/donation contracts
over the code that claims to be device-resident.

Three passes, one report:

  ``jaxpr_audit``  lowers each registered hot entry point to its ClosedJaxpr
                   and walks it: host callbacks, float64 leakage on device
                   tiers, dynamic shapes, donation declared-but-unapplicable,
                   and a Pallas VMEM/grid budget estimator over every
                   ``pallas_call`` equation found in the trace.
  ``ast_rules``    repo-specific AST lint: no ``np.*`` / ``.item()`` / host
                   coercions / Python branching on traced values inside
                   jitted functions (and ``while_loop``/``scan`` bodies), no
                   reuse of a donated ring view after a push, and every
                   ``pallas_call`` site must be covered by a registered
                   budget entry.
  ``retrace``      a compile-cache guard asserting a fixed multi-segment
                   ``AdaptiveEngine`` run triggers at most one trace per
                   distinct spec -- and zero on a rerun (the regression
                   detector for the PR 4/5 engine-caching work).

``python -m repro.analysis --baseline analysis-baseline.json`` emits a JSON
report and fails on any finding not in the checked-in baseline; the baseline
is seeded (ideally empty) by fixing current violations once. The same command
runs as a CI gate and as the ``benchmarks/run.py --smoke`` preflight, so a
bench run refuses to measure an impure hot path. DESIGN.md §12 documents the
tier contract table and how to register a new hot path.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Sequence

#: the checked-in baseline at the repo root (src/repro/analysis -> repo)
BASELINE_PATH = pathlib.Path(__file__).resolve().parents[3] / "analysis-baseline.json"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation, stable enough to baseline.

    ``key()`` identifies a finding across runs (pass + rule + location);
    ``detail`` is human context and deliberately excluded from the key so a
    reworded message does not un-baseline an old finding.
    """

    analysis: str  # which pass: 'jaxpr' | 'ast' | 'vmem' | 'donation' | 'retrace'
    rule: str  # machine-readable rule id, e.g. 'host-callback'
    where: str  # entry-point name or file:line
    detail: str = ""

    def key(self) -> str:
        return f"{self.analysis}:{self.rule}:{self.where}"

    def render(self) -> str:
        msg = f"[{self.analysis}/{self.rule}] {self.where}"
        return f"{msg} -- {self.detail}" if self.detail else msg


def load_baseline(path: "pathlib.Path | str | None" = None) -> set[str]:
    """The set of baselined finding keys (empty when no file exists)."""
    p = pathlib.Path(path) if path is not None else BASELINE_PATH
    if not p.exists():
        return set()
    data = json.loads(p.read_text())
    return set(data.get("findings", []))

def write_baseline(findings: Sequence[Finding], path: "pathlib.Path | str | None" = None) -> None:
    p = pathlib.Path(path) if path is not None else BASELINE_PATH
    p.write_text(json.dumps(
        {"findings": sorted({f.key() for f in findings})}, indent=2) + "\n")


def new_findings(findings: Iterable[Finding], baseline: set[str]) -> list[Finding]:
    """Findings not explained by the baseline (the CI failure set)."""
    return [f for f in findings if f.key() not in baseline]


def run_all(retrace: bool = True) -> tuple[list[Finding], dict]:
    """Run every pass; returns (findings, stats) -- the CLI/preflight core."""
    from . import ast_rules, jaxpr_audit
    findings: list[Finding] = []
    stats: dict = {}
    findings += jaxpr_audit.run_jaxpr_audit(stats=stats)
    findings += ast_rules.run_ast_rules(stats=stats)
    if retrace:
        from . import retrace as retrace_mod
        findings += retrace_mod.run_retrace_audit(stats=stats)
    return findings, stats


def preflight(baseline: "pathlib.Path | str | None" = None, retrace: bool = True) -> None:
    """Refuse to proceed (SystemExit) on unbaselined findings.

    ``benchmarks/run.py --smoke`` calls this before measuring anything: a
    bench number taken over an impure hot path (host callback, retrace churn,
    VMEM overflow) is not a measurement of the system the contracts describe.
    """
    findings, _ = run_all(retrace=retrace)
    fresh = new_findings(findings, load_baseline(baseline))
    if fresh:
        for f in fresh:
            print(f"analysis preflight: {f.render()}")
        raise SystemExit(
            f"analysis preflight: {len(fresh)} unbaselined finding(s); "
            "refusing to benchmark an impure hot path "
            "(run `python -m repro.analysis` for the report)")
