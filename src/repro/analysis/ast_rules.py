"""Repo-specific AST lint: host habits that break inside a trace.

The jaxpr audit (``jaxpr_audit``) proves properties of what the registered
entry points *lower to*; this pass reads the source instead, so it covers
every jitted function in the repo -- including ones no registry entry
reaches -- and catches the mistakes before they ever trace:

  np-on-traced       a ``np.*`` call fed a traced value inside a jitted
                     function: numpy pulls the array to host (or fails),
                     silently de-jitting the path.
  host-item /        ``.item()`` / ``float()`` / ``int()`` / ``bool()`` on a
  host-coercion      traced value: a device sync per call (the exact leak
                     PR 4 removed from the per-segment loop).
  traced-branch      Python ``if``/``while`` on a traced value: under jit
                     this is a TracerBoolConversionError at best, a silently
                     trace-time-frozen branch at worst (``jnp.where``/
                     ``lax.cond`` is the device form).
  traced-iteration   Python ``for`` directly over a traced array (iterating
                     static containers -- pytrees, ``zip`` of NamedTuple
                     fields -- is fine and not flagged).
  stale-ring-view    reading a name bound from ``ObservationRing.view()``
                     after a later ``push``/``push_trace`` on the same ring:
                     pushes donate the buffers, so the view's arrays are
                     deleted (``log.ObservationRing.view`` lifetime contract).
  pallas-uncovered   a ``pl.pallas_call`` site in a file outside
                     ``jaxpr_audit.PALLAS_COVERAGE``: every kernel must have
                     a registered entry so its BlockSpecs pass the VMEM /
                     grid-divisibility budget (the estimator runs on the
                     *traced* grid_mapping, which is exact where an AST
                     guess would not be).

What counts as a jitted context (all discovered statically, per module):

  * ``@jax.jit`` / ``@partial(jax.jit, static_argnames=...)`` decorated defs
  * ``g = jax.jit(f)`` and ``g = partial(jax.jit, ...)(f)`` assignments
  * bodies handed to ``lax.while_loop`` / ``scan`` / ``fori_loop`` /
    ``cond`` / ``switch`` (resolved by name, including through lists)
  * Pallas kernel bodies: the first argument of ``pl.pallas_call`` (resolved
    through ``functools.partial(kernel, ...)`` bindings)

Inside a context, taint starts at the non-static parameters (names listed in
``static_argnames`` stay host values) and propagates through assignments.
Shape metadata is *static by construction*: ``x.shape`` / ``.ndim`` /
``.dtype`` / ``.size`` and anything derived from them never taints, which is
what keeps ``if n_steps is None``, ``m, T = log_b.shape`` and
``for k in range(K)`` clean without per-site suppressions.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Iterable

from . import Finding
from .jaxpr_audit import PALLAS_COVERAGE

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
SRC_ROOT = REPO_ROOT / "src" / "repro"

#: attributes that yield static (host) metadata even on a traced array
SHAPE_ATTRS = frozenset({"shape", "ndim", "dtype", "size"})
#: control-flow wrappers whose function-valued args are traced bodies
TRACED_BODY_CALLS = frozenset(
    {"while_loop", "scan", "fori_loop", "cond", "switch", "associative_scan"})
HOST_COERCIONS = frozenset({"float", "int", "bool", "complex"})


def _dotted(node) -> str:
    """'jax.lax.while_loop' for nested Attribute chains ('' if not one)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jax_jit(node) -> bool:
    return _dotted(node) in ("jax.jit", "jit")


def _static_names_of_call(call: ast.Call) -> set[str]:
    """static_argnames from a ``jax.jit(...)`` / ``partial(jax.jit, ...)``."""
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names = set()
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    names.add(el.value)
            return names
    return set()


@dataclasses.dataclass
class JitContext:
    """One function body that runs under trace."""

    fn: ast.FunctionDef
    kind: str  # 'jit' | 'loop-body' | 'pallas-kernel'
    static_names: set[str] = dataclasses.field(default_factory=set)
    all_params_traced: bool = True


def _param_names(fn: ast.FunctionDef, positional_only: bool = False) -> list[str]:
    a = fn.args
    params = list(a.posonlyargs) + list(a.args)
    if not positional_only:
        params += list(a.kwonlyargs)
    return [p.arg for p in params]


def discover_contexts(tree: ast.Module) -> list[JitContext]:
    """Every jitted/traced function body in one module (see module doc)."""
    defs: dict[str, ast.FunctionDef] = {}
    partial_of: dict[str, str] = {}  # x = functools.partial(f, ...) -> f
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node
        elif isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt, val = node.targets[0], node.value
            if (isinstance(tgt, ast.Name) and isinstance(val, ast.Call)
                    and _dotted(val.func).split(".")[-1] == "partial"
                    and val.args and isinstance(val.args[0], ast.Name)):
                partial_of[tgt.id] = val.args[0].id

    out: dict[int, JitContext] = {}

    def add(name_node, kind: str, static: set[str]):
        name = name_node.id if isinstance(name_node, ast.Name) else None
        if name is None and isinstance(name_node, ast.Call):
            # partial(kernel, ...) inline
            f = name_node
            if (_dotted(f.func).split(".")[-1] == "partial" and f.args
                    and isinstance(f.args[0], ast.Name)):
                name = f.args[0].id
        if name in partial_of:
            name = partial_of[name]
        fn = defs.get(name or "")
        if fn is not None and id(fn) not in out:
            out[id(fn)] = JitContext(fn, kind, static)

    for node in ast.walk(tree):
        # decorated defs
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if _is_jax_jit(dec):
                    out[id(node)] = JitContext(node, "jit", set())
                elif isinstance(dec, ast.Call):
                    if _is_jax_jit(dec.func):  # @jax.jit(...)
                        out[id(node)] = JitContext(
                            node, "jit", _static_names_of_call(dec))
                    elif (_dotted(dec.func).split(".")[-1] == "partial"
                          and dec.args and _is_jax_jit(dec.args[0])):
                        out[id(node)] = JitContext(
                            node, "jit", _static_names_of_call(dec))
        if not isinstance(node, ast.Call):
            continue
        callee = _dotted(node.func)
        leaf = callee.split(".")[-1]
        # g = jax.jit(f, ...) / partial(jax.jit, ...)(f)
        if _is_jax_jit(node.func) and node.args and isinstance(node.args[0], ast.Name):
            add(node.args[0], "jit", _static_names_of_call(node))
        if (isinstance(node.func, ast.Call) and _dotted(node.func.func).split(".")[-1] == "partial"
                and node.func.args and _is_jax_jit(node.func.args[0])
                and node.args and isinstance(node.args[0], ast.Name)):
            add(node.args[0], "jit", _static_names_of_call(node.func))
        # control-flow bodies (including lists of branches for switch)
        if leaf in TRACED_BODY_CALLS and callee.split(".")[0] in ("jax", "lax"):
            for arg in node.args:
                if isinstance(arg, (ast.Name, ast.Call)):
                    add(arg, "loop-body", set())
                elif isinstance(arg, (ast.List, ast.Tuple)):
                    for el in arg.elts:
                        add(el, "loop-body", set())
        # pallas kernels
        if leaf == "pallas_call" and node.args:
            add(node.args[0], "pallas-kernel", set())
    return list(out.values())


class _TaintLinter(ast.NodeVisitor):
    """Walk one traced function body, propagating taint and flagging."""

    def __init__(self, ctx: JitContext, rel: str,
                 traced_body_ids: set[int]):
        self.ctx = ctx
        self.rel = rel
        self.traced_body_ids = traced_body_ids  # defs that are loop bodies
        # in loop bodies and pallas kernels arrays arrive positionally
        # (carries, refs); keyword-only params are partial-bound config
        pos_only = ctx.kind != "jit"
        self.tainted: set[str] = {
            p for p in _param_names(ctx.fn, positional_only=pos_only)
            if p not in ctx.static_names}
        self.findings: list[Finding] = []

    def _flag(self, rule: str, node, detail: str):
        self.findings.append(Finding(
            "ast", rule, f"{self.rel}:{node.lineno}",
            f"{detail} (in `{self.ctx.fn.name}`, {self.ctx.kind})"))

    # -- taint classification ------------------------------------------------
    def _is_tainted(self, node) -> bool:
        t = self._is_tainted
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in SHAPE_ATTRS:
                return False  # static metadata, even of a traced array
            return t(node.value)
        if isinstance(node, ast.Subscript):
            return t(node.value)
        if isinstance(node, ast.Call):
            return any(map(t, node.args)) or any(
                t(kw.value) for kw in node.keywords) or t(node.func)
        if isinstance(node, (ast.BinOp,)):
            return t(node.left) or t(node.right)
        if isinstance(node, ast.BoolOp):
            return any(map(t, node.values))
        if isinstance(node, ast.Compare):
            return t(node.left) or any(map(t, node.comparators))
        if isinstance(node, ast.UnaryOp):
            return t(node.operand)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(map(t, node.elts))
        if isinstance(node, ast.IfExp):
            return t(node.test) or t(node.body) or t(node.orelse)
        if isinstance(node, ast.Starred):
            return t(node.value)
        if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
            return any(
                isinstance(n, ast.Name) and n.id in self.tainted
                for n in ast.walk(node))
        return False

    def _taint_target(self, target):
        if isinstance(target, ast.Name):
            self.tainted.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._taint_target(el)
        elif isinstance(target, ast.Starred):
            self._taint_target(target.value)

    # -- statements ----------------------------------------------------------
    def visit_Assign(self, node):
        self.visit(node.value)
        if self._is_tainted(node.value):
            for tgt in node.targets:
                self._taint_target(tgt)

    def visit_AugAssign(self, node):
        self.visit(node.value)
        if self._is_tainted(node.value):
            self._taint_target(node.target)

    def visit_AnnAssign(self, node):
        if node.value is not None:
            self.visit(node.value)
            if self._is_tainted(node.value):
                self._taint_target(node.target)

    def visit_If(self, node):
        if self._is_tainted(node.test):
            self._flag("traced-branch", node,
                       "Python `if` on a traced value -- use jnp.where/lax.cond")
        self.generic_visit(node)

    def visit_While(self, node):
        if self._is_tainted(node.test):
            self._flag("traced-branch", node,
                       "Python `while` on a traced value -- use lax.while_loop")
        self.generic_visit(node)

    def visit_For(self, node):
        # only a *bare* traced array (Name/Attribute) flags: iterating static
        # containers, pytrees, zip(...) of NamedTuple fields is host-legal
        if isinstance(node.iter, (ast.Name, ast.Attribute)) and self._is_tainted(node.iter):
            self._flag("traced-iteration", node,
                       "Python `for` over a traced array -- use lax.scan/fori_loop")
        self.generic_visit(node)

    def visit_Call(self, node):
        callee = _dotted(node.func)
        root, leaf = (callee.split(".")[0], callee.split(".")[-1]) if callee else ("", "")
        args_tainted = any(map(self._is_tainted, node.args)) or any(
            self._is_tainted(kw.value) for kw in node.keywords)
        if root in ("np", "numpy") and args_tainted:
            self._flag("np-on-traced", node,
                       f"`{callee}` on a traced value forces a host sync")
        if (isinstance(node.func, ast.Attribute) and node.func.attr == "item"
                and self._is_tainted(node.func.value)):
            self._flag("host-item", node,
                       "`.item()` on a traced value is a device sync")
        if (isinstance(node.func, ast.Name) and node.func.id in HOST_COERCIONS
                and args_tainted):
            self._flag("host-coercion", node,
                       f"`{node.func.id}()` on a traced value is a device sync")
        self.generic_visit(node)

    def visit_FunctionDef(self, node):
        if node is self.ctx.fn:
            self.generic_visit(node)
            return
        # nested def: keep the enclosing taint (closures), add its own params
        # as traced only when it is itself a registered traced body
        if id(node) in self.traced_body_ids:
            self.tainted.update(_param_names(node, positional_only=True))
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def run(self) -> list[Finding]:
        self.visit(self.ctx.fn)
        return self.findings


# -- stale ring views ----------------------------------------------------------

class _RingViewLinter(ast.NodeVisitor):
    """Flag reads of a ``.view()`` binding after a later push on the same ring.

    Statement order within one function body is a sound-enough
    approximation: pushes donate the ring's buffers, deleting the arrays any
    earlier view still references (``ObservationRing.view`` lifetime note).
    """

    def __init__(self, rel: str):
        self.rel = rel
        self.findings: list[Finding] = []

    def visit_FunctionDef(self, node):
        views: dict[str, str] = {}  # view var -> ring expression text
        poisoned: dict[str, int] = {}  # view var -> push lineno
        for sub in ast.walk(node):
            if isinstance(sub, ast.Assign) and isinstance(sub.value, ast.Call):
                call = sub.value
                if (isinstance(call.func, ast.Attribute) and call.func.attr == "view"
                        and len(sub.targets) == 1
                        and isinstance(sub.targets[0], ast.Name)):
                    views[sub.targets[0].id] = _dotted(call.func.value)
            elif isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute):
                if sub.func.attr in ("push", "push_trace"):
                    ring = _dotted(sub.func.value)
                    for var, src in views.items():
                        if src == ring and var not in poisoned:
                            poisoned[var] = sub.lineno
            elif isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
                if sub.id in poisoned and sub.lineno > poisoned[sub.id]:
                    self.findings.append(Finding(
                        "ast", "stale-ring-view", f"{self.rel}:{sub.lineno}",
                        f"`{sub.id}` (a ring view) read after the push at "
                        f"line {poisoned[sub.id]} donated its buffers"))
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef


# -- driver --------------------------------------------------------------------

def iter_source_files() -> "Iterable[pathlib.Path]":
    yield from sorted(SRC_ROOT.rglob("*.py"))


def lint_file(path: pathlib.Path) -> tuple[list[Finding], dict]:
    rel = str(path.relative_to(REPO_ROOT))
    tree = ast.parse(path.read_text(), filename=rel)
    contexts = discover_contexts(tree)
    traced_ids = {id(c.fn) for c in contexts}
    findings: list[Finding] = []
    for ctx in contexts:
        findings += _TaintLinter(ctx, rel, traced_ids).run()

    ring = _RingViewLinter(rel)
    ring.visit(tree)
    findings += ring.findings

    n_pallas = 0
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and _dotted(node.func).split(".")[-1] == "pallas_call"):
            n_pallas += 1
            if rel not in PALLAS_COVERAGE:
                findings.append(Finding(
                    "ast", "pallas-uncovered", f"{rel}:{node.lineno}",
                    "pallas_call site outside jaxpr_audit.PALLAS_COVERAGE: "
                    "register a HotEntry so its BlockSpecs are budget-checked"))
    info = {"contexts": len(contexts), "pallas_sites": n_pallas}
    return findings, info


def run_ast_rules(stats: "dict | None" = None) -> list[Finding]:
    findings: list[Finding] = []
    n_files = n_ctx = n_sites = 0
    for path in iter_source_files():
        fs, info = lint_file(path)
        findings += fs
        n_files += 1
        n_ctx += info["contexts"]
        n_sites += info["pallas_sites"]
    if stats is not None:
        stats["ast"] = {"files": n_files, "jit_contexts": n_ctx,
                        "pallas_sites": n_sites,
                        "findings": len(findings)}
    return findings
