"""``python -m repro.analysis``: run every pass, emit the JSON report.

Exit status is the CI contract: 0 when every finding is explained by the
baseline, 1 otherwise. ``--write-baseline`` triages the current findings
into the baseline file (used once, at adoption, to seed it -- ideally
empty); ``--no-retrace`` skips the compile-cache guard (the one pass that
executes programs rather than just tracing them).
"""
from __future__ import annotations

import argparse
import json
import sys

from . import (
    BASELINE_PATH,
    load_baseline,
    new_findings,
    run_all,
    write_baseline,
)


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="device-purity auditor: jaxpr + AST + retrace passes")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help=f"baseline JSON (default: {BASELINE_PATH.name} at the repo root)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings as the new baseline and exit 0")
    ap.add_argument("--no-retrace", action="store_true",
                    help="skip the compile-cache guard pass (fast, trace-only run)")
    ap.add_argument("--json", action="store_true",
                    help="emit the full machine-readable report on stdout")
    args = ap.parse_args(argv)

    findings, stats = run_all(retrace=not args.no_retrace)
    baseline = load_baseline(args.baseline)
    fresh = new_findings(findings, baseline)

    report = {
        "findings": [vars(f) for f in findings],
        "new": [f.key() for f in fresh],
        "baselined": sorted(baseline),
        "stats": stats,
    }
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        for f in findings:
            mark = "NEW " if f in fresh else "base"
            print(f"[{mark}] {f.render()}")
        n_entries = len(stats.get("jaxpr", {}))
        ast_stats = stats.get("ast", {})
        print(f"audited {n_entries} hot entries, "
              f"{ast_stats.get('jit_contexts', 0)} jitted contexts in "
              f"{ast_stats.get('files', 0)} files, "
              f"{ast_stats.get('pallas_sites', 0)} pallas sites: "
              f"{len(findings)} finding(s), {len(fresh)} new")

    if args.write_baseline:
        write_baseline(findings, args.baseline)
        print(f"baseline written ({len(findings)} finding(s))")
        return 0
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
